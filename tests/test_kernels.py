"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles
(interpret mode executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hashing
from repro.core import filter as jf
from repro.kernels import ref
from repro.kernels.fingerprint import fingerprint_hash
from repro.kernels.flash_attention import flash_attention
from repro.kernels.insert import insert_once
from repro.kernels.probe import probe

from conftest import random_keys

pytestmark = pytest.mark.tier1


def _pair(keys):
    hi, lo = hashing.key_to_u32_pair_np(keys)
    return jnp.asarray(hi), jnp.asarray(lo)


@pytest.mark.parametrize("n,block", [(1024, 256), (4096, 1024), (512, 512)])
@pytest.mark.parametrize("fp_bits", [8, 16, 24])
@pytest.mark.parametrize("n_buckets", [777, 1024, 65536])
def test_fingerprint_kernel_sweep(rng, n, block, fp_bits, n_buckets):
    hi, lo = _pair(random_keys(rng, n))
    fp, i1, i2 = fingerprint_hash(hi, lo, fp_bits=fp_bits,
                                  n_buckets=n_buckets, block=block,
                                  interpret=True)
    rfp, ri1, ri2 = ref.fingerprint_ref(hi, lo, fp_bits=fp_bits,
                                        n_buckets=n_buckets)
    np.testing.assert_array_equal(np.asarray(fp), np.asarray(rfp))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(ri1))
    np.testing.assert_array_equal(np.asarray(i2), np.asarray(ri2))


@pytest.mark.parametrize("n_buckets,bucket_size", [(256, 4), (1024, 4),
                                                   (513, 8)])
def test_probe_kernel_sweep(rng, n_buckets, bucket_size):
    keys = random_keys(rng, 2048)
    hi, lo = _pair(keys)
    st = jf.make_state(n_buckets, bucket_size)
    st, ok = jf.bulk_insert(st, hi, lo, fp_bits=16)
    probes = np.concatenate([keys, random_keys(rng, 2048)])
    phi, plo = _pair(probes)
    got = probe(st.table, phi, plo, fp_bits=16, block=1024, interpret=True)
    want = ref.probe_ref(st.table, phi, plo, fp_bits=16)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("n_buckets,bucket_size,n", [(512, 4, 1024),
                                                     (777, 4, 512),
                                                     (1024, 8, 1024)])
def test_insert_kernel_matches_ref_single_block(rng, n_buckets, bucket_size,
                                                n):
    """One kernel block == the jnp optimistic round, table-for-table."""
    keys = random_keys(rng, n)
    hi, lo = _pair(keys)
    table = jf.make_state(n_buckets, bucket_size).table
    t_k, ok_k = insert_once(table, hi, lo, fp_bits=16, block=n,
                            interpret=True)
    t_r, ok_r = ref.insert_once_ref(table, hi, lo, fp_bits=16)
    np.testing.assert_array_equal(np.asarray(t_k), np.asarray(t_r))
    np.testing.assert_array_equal(np.asarray(ok_k), np.asarray(ok_r))


def test_insert_kernel_multi_block_accumulates(rng):
    """Grid steps share the aliased table: placements accumulate, never
    collide, and every placed key is findable by the probe kernel."""
    keys = random_keys(rng, 4096)
    hi, lo = _pair(keys)
    table = jf.make_state(2048, 4).table
    t, ok = insert_once(table, hi, lo, fp_bits=16, block=512, interpret=True)
    placed = int(np.asarray(ok).sum())
    assert int((np.asarray(t) != 0).sum()) == placed
    hits = probe(t, hi, lo, fp_bits=16, block=1024, interpret=True)
    assert np.asarray(hits)[np.asarray(ok)].all()


def test_insert_kernel_respects_active_region(rng):
    """With active < buffer, no fingerprint lands past the active buckets."""
    keys = random_keys(rng, 1024)
    hi, lo = _pair(keys)
    st = jf.make_state(300, 4, buffer_buckets=512)
    t, ok = insert_once(st.table, hi, lo, fp_bits=16,
                        n_buckets=st.n_buckets, block=512, interpret=True)
    assert not np.asarray(t)[300:].any()
    got = probe(t, hi, lo, fp_bits=16, n_buckets=st.n_buckets, block=1024,
                interpret=True)
    assert np.asarray(got)[np.asarray(ok)].all()


def test_probe_kernel_buffered_matches_ref(rng):
    """Probe with an SMEM active count over a larger buffer == ref path."""
    keys = random_keys(rng, 2048)
    hi, lo = _pair(keys)
    st = jf.make_state(400, 4, buffer_buckets=1024)
    st, _ = jf.bulk_insert(st, hi[:1000], lo[:1000], fp_bits=16)
    got = probe(st.table, hi, lo, fp_bits=16, n_buckets=st.n_buckets,
                block=1024, interpret=True)
    want = ref.probe_ref(st.table, hi, lo, fp_bits=16,
                         n_buckets=st.n_buckets)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


ATTN_CASES = [
    # b, hq, hkv, sq, skv, d, causal, window, softcap
    (2, 4, 2, 128, 128, 64, True, None, None),
    (1, 8, 1, 256, 256, 64, True, 64, None),      # GQA 8:1 + window
    (2, 2, 2, 128, 256, 128, True, None, 30.0),   # softcap + longer kv
    (1, 4, 4, 1, 384, 64, True, None, None),      # decode-style q
    (1, 2, 1, 128, 128, 32, False, None, None),   # non-causal (cross-attn)
]


@pytest.mark.parametrize("case", ATTN_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(rng, case, dtype):
    b, hq, hkv, sq, skv, d, causal, window, cap = case
    q = jnp.asarray(rng.randn(b, hq, sq, d), dtype)
    k = jnp.asarray(rng.randn(b, hkv, skv, d), dtype)
    v = jnp.asarray(rng.randn(b, hkv, skv, d), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          logit_softcap=cap, interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal, window=window,
                             logit_softcap=cap)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_local_attention_matches_full(rng):
    for (s, w) in [(256, 64), (512, 128), (128, 128)]:
        q = jnp.asarray(rng.randn(2, 4, s, 32), jnp.float32)
        k = jnp.asarray(rng.randn(2, 2, s, 32), jnp.float32)
        v = jnp.asarray(rng.randn(2, 2, s, 32), jnp.float32)
        if s > w:
            got = ref.local_attention(q, k, v, window=w)
        else:
            got = ref.blockwise_attention(q, k, v, causal=True, window=w)
        want = ref.attention_ref(q, k, v, causal=True, window=w)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-6, rtol=2e-6)


def test_blockwise_matches_full_with_chunking(rng):
    q = jnp.asarray(rng.randn(1, 4, 1024, 32), jnp.float32)
    k = jnp.asarray(rng.randn(1, 2, 1024, 32), jnp.float32)
    v = jnp.asarray(rng.randn(1, 2, 1024, 32), jnp.float32)
    got = ref.blockwise_attention(q, k, v, causal=True, q_chunk=128)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-6, rtol=2e-6)


def test_ops_filter_lookup_pallas_vs_ref(rng):
    from repro.kernels import ops
    keys = random_keys(rng, 3000)
    hi, lo = _pair(keys)
    st = jf.make_state(1024, 4)
    st, _ = jf.bulk_insert(st, hi, lo, fp_bits=16)
    a = np.asarray(ops.filter_lookup(st.table, hi, lo, fp_bits=16,
                                     use_pallas="always"))
    b = np.asarray(ops.filter_lookup(st.table, hi, lo, fp_bits=16,
                                     use_pallas="never"))
    np.testing.assert_array_equal(a, b)
    assert a.all()
