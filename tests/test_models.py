"""Per-architecture smoke tests (reduced configs, CPU) + decode consistency.

Every assigned architecture: one forward + one train step; output shapes and
finiteness asserted.  Decode-vs-full consistency in f32 (bf16 differs only
by rounding asymmetry between cache and no-cache paths).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.distributed.sharding import ParallelConfig
from repro.models import Transformer
from repro.optim.adamw import AdamW
from repro.train.step import make_train_step

KEY = jax.random.PRNGKey(0)


def _inputs(cfg, B, S):
    kwargs = {}
    if cfg.prefix_embed_len:
        kwargs["prefix_embeds"] = 0.01 * jax.random.normal(
            KEY, (B, cfg.prefix_embed_len, cfg.d_model), jnp.float32)
    if cfg.cross_attn_memory_len:
        kwargs["memory"] = 0.01 * jax.random.normal(
            KEY, (B, cfg.cross_attn_memory_len, cfg.cross_attn_memory_dim),
            jnp.float32)
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    return tokens, kwargs


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_smoke_config(arch)
    model = Transformer(cfg)
    params, specs = model.init(KEY)
    assert jax.tree.structure(params) == jax.tree.structure(
        specs, is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(e, (str, type(None))) for e in x))
    B, S = 2, 32
    tokens, kwargs = _inputs(cfg, B, S)
    out = model.apply(params, tokens, **kwargs)
    assert out.logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(out.logits)))


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step_loss_finite(arch):
    cfg = dataclasses.replace(get_smoke_config(arch), dtype="float32")
    model = Transformer(cfg)
    params, _ = model.init(KEY)
    tx = AdamW(lr=1e-3)
    opt = tx.init(params)
    step = make_train_step(model, tx, ParallelConfig())
    B, S = 2, 16
    tokens, kwargs = _inputs(cfg, B, S)
    batch = {"tokens": tokens,
             "targets": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)}
    if "prefix_embeds" in kwargs:
        batch["prefix_embeds"] = kwargs["prefix_embeds"]
    if "memory" in kwargs:
        batch["memory"] = kwargs["memory"]
    params2, opt2, metrics = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually changed
    delta = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                         params, params2)
    assert max(jax.tree.leaves(delta)) > 0


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if "llava" not in a])
def test_decode_matches_full_f32(arch):
    cfg = dataclasses.replace(get_smoke_config(arch), dtype="float32")
    model = Transformer(cfg)
    params, _ = model.init(KEY)
    B, S = 2, 32
    tokens, kwargs = _inputs(cfg, B, S)
    mem = kwargs.get("memory")
    out = model.apply(params, tokens, memory=mem)
    cache = model.init_cache(B, S + 4, dtype=jnp.float32)
    pre = model.apply(params, tokens[:, :S - 1], cache=cache, cache_pos=0,
                      memory=mem)
    dec = model.decode_step(params, pre.cache, tokens[:, S - 1:S],
                            jnp.int32(S - 1), memory=mem)
    err = float(jnp.max(jnp.abs(
        jax.nn.log_softmax(out.logits[:, -1])
        - jax.nn.log_softmax(dec.logits[:, 0]))))
    assert err < 1e-3, f"{arch}: decode diverges from full forward ({err})"


def test_windowed_ring_cache_matches_full():
    """window_bound decode (ring KV) == full-cache decode for local arch."""
    cfg = dataclasses.replace(get_smoke_config("recurrentgemma_2b"),
                              dtype="float32")
    model = Transformer(cfg)
    params, _ = model.init(KEY)
    B, S = 1, 48
    tokens, _ = _inputs(cfg, B, S)
    full_cache = model.init_cache(B, S, dtype=jnp.float32)
    ring_cache = model.init_cache(B, S, dtype=jnp.float32, window_bound=True)
    lf, lc = None, None
    for t in range(S):
        of = model.decode_step(params, full_cache, tokens[:, t:t + 1],
                               jnp.int32(t))
        orr = model.decode_step(params, ring_cache, tokens[:, t:t + 1],
                                jnp.int32(t))
        full_cache, ring_cache = of.cache, orr.cache
        lf, lc = of.logits, orr.logits
    np.testing.assert_allclose(np.asarray(lf), np.asarray(lc), atol=1e-4)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_param_count_matches_family_size(arch):
    cfg = get_config(arch)
    n = cfg.param_count()
    expected = {
        "llava_next_mistral_7b": 7.1e9, "mistral_nemo_12b": 11.6e9,
        "gemma3_1b": 1.0e9, "nemotron_4_15b": 15.6e9, "gemma2_27b": 27.2e9,
        "deepseek_v2_lite_16b": 15.5e9, "qwen3_moe_235b_a22b": 235e9,
        "mamba2_1p3b": 1.34e9, "recurrentgemma_2b": 2.9e9,
        "musicgen_large": 3.2e9,
    }[arch]
    assert abs(n - expected) / expected < 0.05
