"""PRE / EOF resize-policy unit tests (paper Alg. 1 semantics)."""
import pytest

from repro.core.policy import EofPolicy, PrePolicy, O_SAFE

pytestmark = pytest.mark.tier1


def test_pre_grows_by_doubling():
    p = PrePolicy(o_max=0.85, o_min=0.25, c_min=1024)
    d = p.observe(items=900, capacity=1024)
    assert d is not None and d.reason == "grow" and d.new_capacity == 2048


def test_pre_shrinks_by_tenth():
    p = PrePolicy(o_max=0.85, o_min=0.25, c_min=1024)
    d = p.observe(items=500, capacity=4096)
    assert d is not None and d.reason == "shrink"
    assert d.new_capacity == 4096 - 4096 // 10


def test_pre_respects_c_bounds():
    p = PrePolicy(c_min=2048, c_max=4096)
    assert p.observe(items=100, capacity=2048) is None  # at c_min
    d = p.observe(items=4000, capacity=4096)
    assert d is None  # at c_max, growth clamps back to c_max -> no-op


def test_pre_unsafe_shrink_prevented():
    p = PrePolicy(o_max=0.85, o_min=0.25, c_min=16)
    # shrink by 10% would exceed safe load: clamp keeps occupancy <= O_SAFE
    d = p.observe(items=230, capacity=1024)
    assert d is None or d.new_capacity * O_SAFE >= 230


def test_eof_requires_marker_arming():
    p = EofPolicy(k_min=0.35, k_max=0.75, o_max=0.85, o_min=0.25)
    # crossing k_max arms monitoring but does not resize
    assert p.observe(items=790, capacity=1024, ops=10) is None
    assert p.monitoring
    # occupancy recedes into the marker band: disarm
    assert p.observe(items=500, capacity=1024, ops=10) is None
    assert not p.monitoring


def test_eof_resize_after_threshold_cross():
    p = EofPolicy(k_min=0.35, k_max=0.75, o_max=0.85, o_min=0.25, gain=1 / 16)
    assert p.observe(items=790, capacity=1024, ops=100) is None  # arm
    d = p.observe(items=900, capacity=1024, ops=200)             # cross O_max
    assert d is not None and d.reason == "grow"
    assert d.new_capacity > 1024
    assert 0.0 < d.alpha <= 1.0


def test_eof_alpha_ewma_rises_with_faster_bursts():
    p = EofPolicy(k_min=0.35, k_max=0.75, o_max=0.85, o_min=0.25, gain=0.25)
    p.observe(items=790, capacity=1024, ops=1000)
    d1 = p.observe(items=900, capacity=1024, ops=1000)   # slow window
    a1 = d1.alpha
    c = d1.new_capacity
    # second, much faster burst (fewer marked ops to cross)
    p.observe(items=int(c * 0.80), capacity=c, ops=10)
    d2 = p.observe(items=int(c * 0.90), capacity=c, ops=10)
    assert d2 is not None
    assert d2.alpha > a1, "rate ratio M>1 must raise alpha (burst prediction)"


def test_eof_shrink_branch():
    p = EofPolicy(k_min=0.35, k_max=0.75, o_max=0.85, o_min=0.25, c_min=256)
    p.observe(items=300, capacity=1024, ops=50)   # below k_min arms
    d = p.observe(items=200, capacity=1024, ops=50)  # below o_min
    assert d is not None and d.reason == "shrink"
    assert d.new_capacity < 1024
    assert d.new_capacity * O_SAFE >= 200 or d.clamped
