import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.RandomState(0)


def random_keys(rng, n, lo=0, hi=2 ** 63):
    return rng.randint(lo, hi, size=n, dtype=np.int64).astype(np.uint64)
