"""Streaming subsystem validation: overflow stash, TTL generations,
admission backpressure, and the newly-unlocked sharded Pallas probe.

Covers the ISSUE-4 acceptance criteria:
  * with ``backend="pallas"`` at 0.9 load, an eviction-storm insert batch
    that previously reported failures lands EVERY key via the stash,
    parity-checked against the stash-extended pyfilter oracle;
  * single-lane chains reproduce the oracle bit for bit (table AND stash);
  * generation rotation keeps the last K batches visible, TTL expiry is
    lazy, and retirement recycles the preallocated buffer pool;
  * stash occupancy + generation fill drive admission with hysteresis;
  * ``distributed_lookup`` / ``replicated_lookup`` accept the backend flag
    and the Pallas path agrees with jnp inside ``shard_map``;
  * ``evict_rounds`` defaults derive from the configured operating load
    (0.85 -> 32, 0.9 -> 64) instead of the old flat 32;
  * empty batches are safe through every new entry point.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import filter as jf
from repro.core import hashing
from repro.core.filter_ops import FilterOps, evict_rounds_for_load
from repro.core.ocf import OCF, OcfConfig
from repro.kernels import ops as kops
from repro.kernels.insert import insert_bulk
from repro.kernels.stash import make_stash, stash_occupancy
from repro.streaming import (AdmissionConfig, AdmissionController,
                             GenerationConfig, GenerationalFilter,
                             PyStashFilter, congestion_signal)

from conftest import random_keys

pytestmark = pytest.mark.tier1


def _pair(keys):
    hi, lo = hashing.key_to_u32_pair_np(keys)
    return jnp.asarray(hi), jnp.asarray(lo)


# ------------------------------------------------------------ stash core --


def test_stash_single_lane_bit_for_bit_oracle(rng):
    """One key per kernel call == the sequential oracle's chain schedule:
    table and stash bit-for-bit, through spill AND stash-full rollback."""
    n_buckets, bs, rounds, slots = 64, 4, 8, 16
    oracle = PyStashFilter(n_buckets=n_buckets, bucket_size=bs, fp_bits=16,
                           evict_rounds=rounds, stash_slots=slots)
    table = jnp.zeros((n_buckets, bs), jnp.uint32)
    stash = make_stash(slots)
    keys = random_keys(rng, 300)
    ok_k, ok_o = [], []
    for k in keys:
        hi, lo = _pair(np.array([k], dtype=np.uint64))
        table, stash, ok = insert_bulk(table, hi, lo, fp_bits=16,
                                       evict_rounds=rounds, stash=stash,
                                       block=1, interpret=True)
        ok_k.append(bool(np.asarray(ok)[0]))
        ok_o.append(oracle.insert(int(k)))
    np.testing.assert_array_equal(np.array(ok_k), np.array(ok_o))
    np.testing.assert_array_equal(np.asarray(table), oracle.table)
    np.testing.assert_array_equal(np.asarray(stash), oracle.stash_array())
    assert oracle.spills == slots, "stash must have filled"
    assert not all(ok_k), "stash-full rollback must have been exercised"


def test_eviction_storm_lands_all_keys_via_stash(rng):
    """ISSUE-4 acceptance: the PR-3 eviction-storm workload (0.94-load
    table + oversized burst + tiny round budget) that reported failures
    without a stash now lands EVERY key, with fingerprint conservation and
    membership parity on the pallas backend."""
    base = random_keys(rng, 240)            # 240 / 256 slots = 0.94
    bhi, blo = _pair(base)
    st = jf.make_state(64, 4)
    st, ok_base = jf.bulk_insert(st, bhi, blo, fp_bits=16)
    extra = random_keys(rng, 64)
    ehi, elo = _pair(extra)
    # without a stash the storm overflows the budget (PR-3 behavior) ...
    _t0, ok0 = insert_bulk(st.table, ehi, elo, fp_bits=16, block=64,
                           evict_rounds=8, interpret=True)
    assert not np.asarray(ok0).all(), "storm must overflow without a stash"
    # ... with one, every key lands
    t, stash, ok = insert_bulk(st.table, ehi, elo, fp_bits=16, block=64,
                               evict_rounds=8, stash=make_stash(128),
                               interpret=True)
    ok = np.asarray(ok)
    assert ok.all(), "stash must absorb the whole storm"
    spilled = int(stash_occupancy(stash))
    assert spilled > 0
    placed_base = int(np.asarray(ok_base).sum())
    assert int((np.asarray(t) != 0).sum()) + spilled == placed_base + 64
    # every key (base + storm) answers True through the fused stash probe
    allhi = jnp.concatenate([bhi, ehi])
    alllo = jnp.concatenate([blo, elo])
    hit = kops.filter_lookup(t, allhi, alllo, fp_bits=16, stash=stash,
                             use_pallas="always")
    mask = np.concatenate([np.asarray(ok_base), ok])
    assert np.asarray(hit)[mask].all()


def test_storm_parity_vs_stash_oracle_membership(rng):
    """Batched storm vs the stash-extended oracle: same per-key membership
    answers and the same total state size (multi-lane schedules may place
    fingerprints differently; membership and conservation may not)."""
    keys = random_keys(rng, 920)            # 920 / 1024 slots = 0.9 load
    hi, lo = _pair(keys)
    rounds = evict_rounds_for_load(0.9)
    oracle = PyStashFilter(n_buckets=256, bucket_size=4, fp_bits=16,
                           evict_rounds=rounds, stash_slots=128)
    ok_o = np.array([oracle.insert(int(k)) for k in keys])
    table, stash, ok = insert_bulk(
        jnp.zeros((256, 4), jnp.uint32), hi, lo, fp_bits=16,
        evict_rounds=rounds, stash=make_stash(128), block=920,
        interpret=True)
    ok = np.asarray(ok)
    assert ok.all() and ok_o.all()
    assert (int((np.asarray(table) != 0).sum()) + int(stash_occupancy(stash))
            == oracle.count + len(oracle.stash))
    hit = kops.filter_lookup(table, hi, lo, fp_bits=16, stash=stash,
                             use_pallas="always")
    hit_o = np.array([oracle.lookup(int(k)) for k in keys])
    np.testing.assert_array_equal(np.asarray(hit), hit_o)


def test_stash_lookup_kernel_vs_ref_arm(rng):
    """ops.filter_lookup with a stash: the fused kernel arm and the jnp
    ref arm answer identically (dispatch can't change answers)."""
    keys = random_keys(rng, 500)
    hi, lo = _pair(keys)
    st = jf.make_state(64, 4)               # tiny: guarantees spills
    t, stash, ok = kops.filter_insert(st.table, hi, lo, fp_bits=16,
                                      evict_rounds=8, stash=make_stash(64),
                                      use_pallas="always")
    probes = np.concatenate([keys, random_keys(rng, 500)])
    phi, plo = _pair(probes)
    h_k = kops.filter_lookup(t, phi, plo, fp_bits=16, stash=stash,
                             use_pallas="always")
    h_r = kops.filter_lookup(t, phi, plo, fp_bits=16, stash=stash,
                             use_pallas="never")
    np.testing.assert_array_equal(np.asarray(h_k), np.asarray(h_r))


def test_filter_ops_insert_spill_count_and_backends(rng):
    """FilterOps.insert_spill: state.count tracks table-resident
    fingerprints only (stash counted separately), and both backends land
    the same lanes."""
    keys = random_keys(rng, 300)
    hi, lo = _pair(keys)
    for backend in ("pallas", "jnp"):
        fops = FilterOps(fp_bits=16, backend=backend, evict_rounds=8)
        st = jf.make_state(64, 4)
        st, stash, ok = fops.insert_spill(st, make_stash(64), hi, lo)
        assert np.asarray(ok).all()
        spilled = int(stash_occupancy(stash))
        assert spilled > 0, "workload must spill"
        assert int(st.count) == int((np.asarray(st.table) != 0).sum())
        assert int(st.count) + spilled == 300
        hits = fops.lookup_with_stash(st, stash, hi, lo)
        assert np.asarray(hits).all()


# --------------------------------------------------------- generations ---


def test_generation_rotation_keeps_last_k_visible(rng):
    """Explicit rotation: the ring keeps exactly the last K generations'
    keys visible and drops the one rotated past, on the pallas backend."""
    cfg = GenerationConfig(generations=3, capacity=2048, stash_slots=32,
                           backend="pallas", ttl=None)
    gf = GenerationalFilter(cfg, now=0.0)
    batches = [random_keys(rng, 700) for _ in range(4)]
    for i, b in enumerate(batches):
        assert gf.insert(b, now=float(i)).all()
        if i < len(batches) - 1:
            gf.rotate(now=float(i))     # seal this batch's generation
    assert gf.stats.rotations == 3
    assert gf.live_generations == 3
    for b in batches[-3:]:
        assert gf.lookup(b, now=10.0).all()
    # the first batch aged out of the ring (false positives only)
    assert not gf.lookup(batches[0], now=10.0).all()


def test_generation_ttl_lazy_expiry_and_pool_reuse(rng):
    cfg = GenerationConfig(generations=2, capacity=1024, stash_slots=32,
                           backend="jnp", ttl=10.0)
    gf = GenerationalFilter(cfg, now=0.0)
    keys = random_keys(rng, 600)
    assert gf.insert(keys, now=0.0).all()
    assert gf.lookup(keys, now=9.9).all()
    # lazy: no advance() call, yet an expired generation answers nothing
    assert not gf.lookup(keys, now=10.1).any()
    assert gf.stats.expirations == 0        # not reclaimed yet
    assert gf.advance(now=10.1) == 1        # now it is
    assert gf.stats.expirations == 1
    # the ring keeps running on the recycled pool buffer
    k2 = random_keys(rng, 600)
    assert gf.insert(k2, now=11.0).all()
    assert gf.lookup(k2, now=12.0).all()
    assert gf.pool.shape == gf.active.state.table.shape


def test_generation_insert_failure_rotates_and_retries(rng):
    """A burst larger than table+stash rotates early and retries once —
    ok stays all-True and the stream keeps accepting."""
    cfg = GenerationConfig(generations=2, capacity=256, stash_slots=16,
                           backend="jnp", evict_rounds=4, o_max=2.0,
                           stash_high=2.0)   # disable proactive rotation
    gf = GenerationalFilter(cfg, now=0.0)
    keys = random_keys(rng, 400)             # > capacity + stash
    ok = gf.insert(keys, now=0.0)
    assert gf.stats.rotate_retries > 0
    assert gf.stats.rotations >= 1
    assert ok.all(), "retry in the fresh generation must land the residue"
    assert gf.lookup(keys, now=0.0).all()


# ----------------------------------------------------------- admission ---


def test_admission_controller_hysteresis(rng):
    cfg = GenerationConfig(generations=2, capacity=512, stash_slots=64,
                           backend="jnp", evict_rounds=4,
                           o_max=0.97, stash_high=2.0)
    gf = GenerationalFilter(cfg, now=0.0)
    ctl = AdmissionController(gf, AdmissionConfig(high_water=0.35,
                                                  low_water=0.1))
    assert ctl.admit(), "idle filter admits"
    gf.insert(random_keys(rng, 480), now=0.0)    # ~0.94 fill (+ spills)
    assert ctl.signal() >= 0.35
    assert not ctl.admit(), "congested filter trips"
    assert ctl.deferred == 1
    gf.rotate(now=1.0)                           # congestion relieved
    assert ctl.signal() <= 0.1
    assert ctl.admit(), "hysteresis resets below low water"
    # signal math is the documented weighted sum
    a = AdmissionConfig(stash_weight=0.5, fill_weight=0.5)
    assert congestion_signal(0.4, 0.8, a) == pytest.approx(0.6)


def test_admission_observe_eof_accelerates_window(rng):
    """observe_eof inflates marked ops by (1 + signal): a congested stream
    must close the EOF monitoring window in fewer observe calls."""
    from repro.core.policy import EofPolicy

    def drive(signal_value):
        cfg = GenerationConfig(generations=2, capacity=512, backend="jnp")
        ctl = AdmissionController(GenerationalFilter(cfg, now=0.0))
        ctl.signal = lambda: signal_value          # pin the congestion
        pol = EofPolicy(c_min=64)
        pol.observe(items=90, capacity=100, ops=1)  # arm the window
        calls = 0
        while calls < 1000:
            calls += 1
            if ctl.observe_eof(pol, items=90, capacity=100, ops=7):
                break
        return pol.t_cur

    # same number of observe calls -> congested run accumulates ~2x the
    # marked ops (and the first resize happens with a larger t_cur)
    assert drive(1.0) > drive(0.0)


def test_scheduler_admission_defers_and_drains(rng):
    """ContinuousBatcher + AdmissionController: submits defer while the
    filter is congested, and a fully-starved batcher recovers on its own —
    the drain path ages the filter (advance, else rotate) when everything
    is deferred and nothing else can move the congestion signal."""
    import dataclasses as dc
    from repro.configs import get_smoke_config
    from repro.models import Transformer
    from repro.serving.scheduler import ContinuousBatcher, Request

    cfg = dc.replace(get_smoke_config("gemma3_1b"), dtype="float32")
    model = Transformer(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    gcfg = GenerationConfig(generations=2, capacity=512, stash_slots=64,
                            backend="jnp", evict_rounds=4,
                            o_max=0.97, stash_high=2.0)
    gf = GenerationalFilter(gcfg, now=0.0)
    ctl = AdmissionController(gf, AdmissionConfig(high_water=0.35,
                                                  low_water=0.1))
    b = ContinuousBatcher(model, params, slots=2, cache_len=64, block=16,
                          admission=ctl)
    gf.insert(random_keys(rng, 480), now=0.0)    # congest the filter
    prompt = rng.randint(0, cfg.vocab_size, 32).astype(np.int32)
    assert not b.submit(Request(rid=0, prompt=prompt, max_new=2))
    assert b.stats.deferred == 1 and len(b.deferred) == 1
    assert b.congestion > 0.35
    # NO manual relief: the batcher is fully starved (everything deferred),
    # so its drain path must age the filter itself and recover.
    stats = b.run_until_drained()
    assert stats.finished == 1
    assert gf.stats.rotations >= 1, "starved drain must rotate the filter"
    assert not b.deferred and not b.queue
    # polling did not inflate the controller's per-request counters
    assert ctl.deferred == 1


def test_generational_prefix_index_promotes_hot_blocks(rng):
    """A continuously-matched prefix survives rotation: match_prefix
    promotes blocks found only in aging generations into the active one
    (multi-level promote-on-read), so hot prefixes never age out."""
    from repro.serving.kvcache import GenerationalPrefixIndex
    idx = GenerationalPrefixIndex(block=32, backend="jnp", capacity=1024,
                                  generations=2, now=0.0)
    hot = rng.randint(0, 1000, size=128).astype(np.uint32)
    idx.admit(hot, now=0.0)
    for t in range(1, 4):                    # 3 rotations > K=2 generations
        assert idx.match_prefix(hot, now=float(t)) == 4   # promotes
        idx.filt.rotate(now=float(t))
    assert idx.match_prefix(hot, now=10.0) == 4, \
        "hot prefix must survive arbitrary rotations via promotion"
    # an unmatched prefix admitted at t=0 would be gone by now
    cold = rng.randint(0, 1000, size=128).astype(np.uint32)
    idx2 = GenerationalPrefixIndex(block=32, backend="jnp", capacity=1024,
                                   generations=2, now=0.0)
    idx2.admit(cold, now=0.0)
    for t in range(1, 4):
        idx2.filt.rotate(now=float(t))
    assert idx2.match_prefix(cold, now=10.0) == 0


# ---------------------------------------------- distributed backend flag --


def test_distributed_backend_flag_pallas_parity(rng):
    """The backend flag reaches the shard-local probe: 'pallas' runs the
    fused kernel inside shard_map (rep-check relaxed) and agrees with jnp
    bit-for-bit.  Single-device mesh — the 8-device routing test lives in
    test_distributed_ocf.py."""
    from repro.core import distributed as dist
    mesh = jax.make_mesh((1,), ("data",))
    keys = random_keys(rng, 1024)
    hi, lo = _pair(keys)
    st = jf.make_state(256, 4)
    st, _ = jf.bulk_insert(st, hi, lo, fp_bits=16)
    sh = dist.ShardedFilterState(tables=st.table[None])
    h_j, _ = dist.distributed_lookup(mesh, "data", sh, hi, lo, fp_bits=16,
                                     backend="jnp")
    h_p, _ = dist.distributed_lookup(mesh, "data", sh, hi, lo, fp_bits=16,
                                     backend="pallas")
    np.testing.assert_array_equal(np.asarray(h_j), np.asarray(h_p))
    r_j = dist.replicated_lookup(sh.tables, hi, lo, fp_bits=16,
                                 backend="jnp")
    r_p = dist.replicated_lookup(sh.tables, hi, lo, fp_bits=16,
                                 backend="pallas")
    np.testing.assert_array_equal(np.asarray(r_j), np.asarray(r_p))


# --------------------------------------------------- evict-round config --


def test_evict_rounds_derive_from_load():
    """The round budget is a function of the operating load, pow2-rounded:
    the ROADMAP's flat 32 becomes the o_max=0.85 point of a curve that
    yields the tests' 64 at 0.9 without ad-hoc overrides."""
    assert evict_rounds_for_load(0.85) == 32
    assert evict_rounds_for_load(0.9) == 64
    assert evict_rounds_for_load(0.95) == 128
    assert evict_rounds_for_load(0.5) == 8
    assert evict_rounds_for_load(0.999) == 256          # clamped
    assert FilterOps().evict_rounds == 32               # default load
    assert OcfConfig().make_filter_ops().evict_rounds == 32
    assert OcfConfig(o_max=0.9).make_filter_ops().evict_rounds == 64
    assert OcfConfig(evict_rounds=16).make_filter_ops().evict_rounds == 16
    g = GenerationConfig(o_max=0.9).make_filter_ops()
    assert g.evict_rounds == 64


def test_ocf_stash_absorbs_storm_without_emergency_grow(rng):
    """OcfConfig.stash_slots: a high-load burst that would have triggered
    failed_inserts + emergency grow parks in the stash instead; lookups
    stay exact and deletes stay safe."""
    keys = random_keys(rng, 1900)
    cfg = OcfConfig(capacity=2048, mode="PRE", backend="pallas",
                    evict_rounds=4, stash_slots=128, o_max=0.98)
    ocf = OCF(cfg)
    ocf.insert(keys)
    assert ocf.stats.stash_spills > 0, "storm must exercise the stash"
    assert ocf.stats.failed_inserts == 0
    assert ocf.stats.resizes == 0
    assert ocf.lookup(keys).all()
    present = ocf.delete(keys[:500])
    assert present.all()
    assert ocf.lookup(keys[500:]).all()


# ------------------------------------------------------------- guards ----


def test_empty_batches_streaming(rng):
    e = jnp.zeros((0,), jnp.uint32)
    st = jf.make_state(64, 4)
    stash = make_stash(16)
    t, s, ok = kops.filter_insert(st.table, e, e, fp_bits=16,
                                  evict_rounds=8, stash=stash,
                                  use_pallas="always")
    assert np.asarray(ok).shape == (0,)
    assert not np.asarray(t).any() and not np.asarray(s).any()
    hit = kops.filter_lookup(st.table, e, e, fp_bits=16, stash=stash,
                             use_pallas="always")
    assert np.asarray(hit).shape == (0,)
    gf = GenerationalFilter(GenerationConfig(generations=2, capacity=512,
                                             backend="jnp"), now=0.0)
    empty = np.zeros((0,), np.uint64)
    assert gf.insert(empty, now=0.0).shape == (0,)
    assert gf.lookup(empty, now=0.0).shape == (0,)
    fops = FilterOps(fp_bits=16, backend="pallas")
    st2, s2, ok2 = fops.insert_spill(st, stash, e, e)
    assert np.asarray(ok2).shape == (0,) and int(st2.count) == 0
    assert np.asarray(fops.lookup_with_stash(st, stash, e, e)).shape == (0,)
