"""Adaptive filtering (PR 7): selector family, four-plane kernels, oracle
parity, false-positive repair, and the reputation/admission tiers.

The parity ladder mirrors the stash tests in ``test_streaming.py``:

  * **Bit-for-bit single-lane**: one key per kernel call makes the kernel's
    chain schedule identical to the sequential ``PyAdaptiveFilter`` oracle,
    so ALL FOUR planes (table, packed selectors, mirror khi/klo) and the
    stash must match entry for entry — through spills, rollback, adaptation,
    and deletes.
  * **interpret == emulate**: the Pallas interpret path and the XLA grid
    emulation must agree bit-for-bit on every output (the emulation is also
    the dispatch fallback arm, so this is the cross-backend contract).
  * **Zero-plane == static**: with an all-zero selector plane the adaptive
    kernels must reproduce the static kernels' tables exactly — sel=0 uses
    the untweaked fingerprint, so adaptivity is free until the first report.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hashing
from repro.kernels import ops as kops
from repro.kernels.delete import delete_bulk_adaptive
from repro.kernels.fingerprint import fingerprint_hash, fingerprint_hash_family
from repro.kernels.insert import insert_bulk, insert_bulk_adaptive
from repro.kernels.selector import sel_pack, sel_unpack
from repro.kernels.stash import make_stash
from repro.streaming.oracle import PyAdaptiveFilter

from conftest import random_keys

pytestmark = pytest.mark.tier1

FP_BITS = 12      # low enough that 4096 probes yield false positives


def _pair(keys):
    hi, lo = hashing.key_to_u32_pair_np(keys)
    return jnp.asarray(hi), jnp.asarray(lo)


def _zero_planes(n_buckets, bucket_size):
    z = jnp.zeros((n_buckets, bucket_size), jnp.uint32)
    return z, jnp.zeros((n_buckets, 1), jnp.uint32), z, z


# ----------------------------------------------------- fingerprint family --


def test_fingerprint_sel_zero_matches_static_and_np_jnp_parity(rng):
    keys = random_keys(rng, 512)
    hi, lo = _pair(keys)
    hin, lon = np.asarray(hi), np.asarray(lo)
    np.testing.assert_array_equal(
        hashing.fingerprint_sel_np(hin, lon, np.uint32(0), 16),
        hashing.fingerprint_np(hin, lon, 16))
    for sel in range(hashing.SEL_VARIANTS):
        a = hashing.fingerprint_sel_np(hin, lon, np.uint32(sel), 16)
        b = np.asarray(hashing.fingerprint_sel(hi, lo, jnp.uint32(sel), 16))
        np.testing.assert_array_equal(a, b)
        assert a.min() >= 1, "family member emitted the EMPTY sentinel"


def test_fingerprint_family_kernel_agrees_with_static(rng):
    keys = random_keys(rng, 256)
    hi, lo = _pair(keys)
    for kw in (dict(emulate=True), dict(interpret=True)):
        fam, i1, i2 = fingerprint_hash_family(hi, lo, fp_bits=FP_BITS,
                                              n_buckets=64, block=128, **kw)
        fp, si1, si2 = fingerprint_hash(hi, lo, fp_bits=FP_BITS,
                                        n_buckets=64, block=128, **kw)
        np.testing.assert_array_equal(np.asarray(fam[0]), np.asarray(fp))
        np.testing.assert_array_equal(np.asarray(i1), np.asarray(si1))
        np.testing.assert_array_equal(np.asarray(i2), np.asarray(si2))


def test_selector_pack_unpack_roundtrip(rng):
    packed = jnp.asarray(rng.randint(0, 2 ** 32, size=(64, 1),
                                     dtype=np.uint32))
    np.testing.assert_array_equal(
        np.asarray(sel_pack(sel_unpack(packed, 16))), np.asarray(packed))
    # unpacked values are 2-bit
    assert int(np.asarray(sel_unpack(packed, 16)).max()) <= 3


# -------------------------------------------------- static parity ladder --


@pytest.mark.parametrize("evict,slots", [(0, 0), (4, 0), (4, 16)])
def test_zero_plane_adaptive_insert_matches_static(rng, evict, slots):
    """All-zero selector plane: adaptive insert == static insert (table and
    placement mask), interpret == emulate, and mirror planes stay
    consistent with the table (fp0 of the mirrored key == stored fp)."""
    nb, bs = 64, 4
    keys = random_keys(rng, 256)
    hi, lo = _pair(keys)
    table0, sels0, khi0, klo0 = _zero_planes(nb, bs)
    kw = dict(fp_bits=FP_BITS, n_buckets=nb, evict_rounds=evict, block=64)
    st = dict(stash=make_stash(slots)) if slots else {}
    res_e = insert_bulk_adaptive(table0, sels0, khi0, klo0, hi, lo,
                                 emulate=True, **kw, **st)
    res_i = insert_bulk_adaptive(table0, sels0, khi0, klo0, hi, lo,
                                 interpret=True, **kw, **st)
    for a, b in zip(res_e, res_i):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    res_s = insert_bulk(table0, hi, lo, emulate=True, **kw, **st)
    np.testing.assert_array_equal(np.asarray(res_e[0]), np.asarray(res_s[0]))
    np.testing.assert_array_equal(np.asarray(res_e[-1]),
                                  np.asarray(res_s[-1]))
    if slots:
        np.testing.assert_array_equal(np.asarray(res_e[4]),
                                      np.asarray(res_s[1]))
    assert not np.asarray(res_e[1]).any(), "insert must write selector 0"
    tbl, khi_t, klo_t = map(np.asarray, (res_e[0], res_e[2], res_e[3]))
    occ = tbl != 0
    np.testing.assert_array_equal(
        hashing.fingerprint_np(khi_t[occ], klo_t[occ], FP_BITS), tbl[occ])


# -------------------------------------------- single-lane oracle parity --


def _insert_single_lane(oracle, keys, state, stash):
    table, sels, khi_t, klo_t = state
    ok_k, ok_o = [], []
    for k in keys:
        hi, lo = _pair(np.array([k], dtype=np.uint64))
        table, sels, khi_t, klo_t, stash, ok = insert_bulk_adaptive(
            table, sels, khi_t, klo_t, hi, lo, fp_bits=oracle.fp_bits,
            n_buckets=oracle.n_buckets, evict_rounds=oracle.evict_rounds,
            stash=stash, block=1, interpret=True)
        ok_k.append(bool(np.asarray(ok)[0]))
        ok_o.append(oracle.insert(int(k)))
    return (table, sels, khi_t, klo_t), stash, ok_k, ok_o


def _assert_planes_match(oracle, state, stash):
    table, sels, khi_t, klo_t = state
    np.testing.assert_array_equal(np.asarray(table), oracle.table)
    np.testing.assert_array_equal(np.asarray(sels),
                                  oracle.sel_plane_array())
    okhi, oklo = oracle.key_planes()
    np.testing.assert_array_equal(np.asarray(khi_t), okhi)
    np.testing.assert_array_equal(np.asarray(klo_t), oklo)
    np.testing.assert_array_equal(np.asarray(stash), oracle.stash_array())


def test_adaptive_single_lane_bit_for_bit_oracle(rng):
    """The full PR-4 contract extended to four planes: single-lane kernel
    calls == the sequential adaptive oracle through spill AND rollback."""
    nb, bs, rounds, slots = 64, 4, 8, 16
    oracle = PyAdaptiveFilter(n_buckets=nb, bucket_size=bs, fp_bits=16,
                              evict_rounds=rounds, stash_slots=slots)
    state, stash, ok_k, ok_o = _insert_single_lane(
        oracle, random_keys(rng, 300), _zero_planes(nb, bs),
        make_stash(slots))
    np.testing.assert_array_equal(np.array(ok_k), np.array(ok_o))
    _assert_planes_match(oracle, state, stash)
    assert oracle.spills == slots, "stash must have filled"
    assert not all(ok_k), "stash-full rollback must have been exercised"


def test_adaptive_report_and_delete_single_lane_oracle(rng):
    """Reports then deletes, one lane at a time, vs the oracle: adaptation
    decisions, all four planes, and the stash stay bit-for-bit."""
    nb, bs, rounds, slots = 64, 4, 8, 64
    oracle = PyAdaptiveFilter(n_buckets=nb, bucket_size=bs, fp_bits=FP_BITS,
                              evict_rounds=rounds, stash_slots=slots)
    keys = random_keys(rng, 220)
    state, stash, ok_k, ok_o = _insert_single_lane(
        oracle, keys, _zero_planes(nb, bs), make_stash(slots))
    assert all(ok_k) and all(ok_o)
    table, sels, khi_t, klo_t = state
    # find false positives among fresh probes and report them one by one
    probes = np.setdiff1d(random_keys(rng, 4096), keys)
    reported = adapted_total = 0
    for k in probes:
        if not oracle.lookup(int(k)):
            continue
        reported += 1
        hi, lo = _pair(np.array([k], dtype=np.uint64))
        table, sels, adapted, resident = kops.adaptive_report(
            table, sels, khi_t, klo_t, hi, lo, fp_bits=FP_BITS,
            n_buckets=nb)
        a_o, r_o = oracle.report_false_positive(int(k))
        assert bool(np.asarray(adapted)[0]) == a_o
        assert bool(np.asarray(resident)[0]) == r_o
        assert not r_o, "probe keys were never inserted"
        adapted_total += int(a_o)
    assert reported > 0, "FP_BITS=12 over 4096 probes must yield FPs"
    assert adapted_total > 0, "at least one table FP must adapt"
    _assert_planes_match(oracle, (table, sels, khi_t, klo_t), stash)
    # a resident key's report must be refused (resident=True, no adaptation)
    hi, lo = _pair(keys[:1])
    t2, s2, adapted, resident = kops.adaptive_report(
        table, sels, khi_t, klo_t, hi, lo, fp_bits=FP_BITS, n_buckets=nb)
    a_o, r_o = oracle.report_false_positive(int(keys[0]))
    assert (bool(np.asarray(resident)[0]), bool(np.asarray(adapted)[0])) \
        == (r_o, a_o) == (True, False)
    np.testing.assert_array_equal(np.asarray(t2), np.asarray(table))
    # delete half the members (some through adapted slots), still parity
    for k in keys[: len(keys) // 2]:
        hi, lo = _pair(np.array([k], dtype=np.uint64))
        table, sels, khi_t, klo_t, ok = delete_bulk_adaptive(
            table, sels, khi_t, klo_t, hi, lo, fp_bits=FP_BITS,
            n_buckets=nb, block=1, interpret=True)
        ok_o = oracle.delete(int(k))
        if not bool(np.asarray(ok)[0]):
            # table miss -> the entry lives in the stash; oracle's delete
            # already cleared it there, kernel path does so via the
            # composed stash delete in kops.adaptive_delete (exercised in
            # test_report_clears_fp_zero_fn below); clear manually here to
            # keep comparing the table planes.
            pass
        else:
            assert ok_o
    np.testing.assert_array_equal(np.asarray(table), oracle.table)
    np.testing.assert_array_equal(np.asarray(sels),
                                  oracle.sel_plane_array())


# ------------------------------------------------- feedback end-to-end --


def test_report_clears_fp_zero_fn(rng):
    """Batched report path (kops.adaptive_report): every adapted false
    positive stops hitting, and NO member is lost — geometry is anchored
    to fp0 so adaptation never moves entries."""
    nb, bs = 64, 4
    keys = random_keys(rng, 256)
    hi, lo = _pair(keys)
    table, sels, khi_t, klo_t, stash, ok = insert_bulk_adaptive(
        *_zero_planes(nb, bs), hi, lo, fp_bits=FP_BITS, n_buckets=nb,
        evict_rounds=8, stash=make_stash(64), block=64, emulate=True)
    assert np.asarray(ok).all()
    probes = np.setdiff1d(random_keys(rng, 4096), keys)
    phi, plo = _pair(probes)
    hits = np.asarray(kops.adaptive_lookup(table, sels, phi, plo,
                                           fp_bits=FP_BITS, n_buckets=nb,
                                           stash=stash))
    fp_idx = np.nonzero(hits)[0]
    assert fp_idx.size > 0
    t2, s2, adapted, resident = kops.adaptive_report(
        table, sels, khi_t, klo_t, phi[fp_idx], plo[fp_idx],
        fp_bits=FP_BITS, n_buckets=nb)
    assert not np.asarray(resident).any()
    hits2 = np.asarray(kops.adaptive_lookup(t2, s2, phi[fp_idx], plo[fp_idx],
                                            fp_bits=FP_BITS, n_buckets=nb,
                                            stash=stash))
    assert not hits2[np.asarray(adapted)].any(), "adapted FP still hits"
    mem = np.asarray(kops.adaptive_lookup(t2, s2, hi, lo, fp_bits=FP_BITS,
                                          n_buckets=nb, stash=stash))
    assert mem.all(), "false negative after adaptation"
    # adaptive probe variants agree with each other
    for kw in (dict(emulate=True), dict(interpret=True)):
        from repro.kernels.probe import probe_adaptive
        h = probe_adaptive(t2, s2, hi, lo, fp_bits=FP_BITS, n_buckets=nb,
                           stash=stash, block=64, **kw)
        np.testing.assert_array_equal(np.asarray(h), mem)


def test_kick_through_adapted_slots_no_false_negatives(rng):
    """Eviction chains crossing adapted buckets re-derive the victim's
    geometry from the mirror key planes — no member may be lost."""
    nb, bs = 64, 4
    keys = random_keys(rng, 256)
    hi, lo = _pair(keys)
    table, sels, khi_t, klo_t, stash, ok = insert_bulk_adaptive(
        *_zero_planes(nb, bs), hi, lo, fp_bits=FP_BITS, n_buckets=nb,
        evict_rounds=8, stash=make_stash(64), block=64, emulate=True)
    assert np.asarray(ok).all()
    probes = np.setdiff1d(random_keys(rng, 4096), keys)
    phi, plo = _pair(probes)
    hits = np.asarray(kops.adaptive_lookup(table, sels, phi, plo,
                                           fp_bits=FP_BITS, n_buckets=nb,
                                           stash=stash))
    table, sels, _, _ = kops.adaptive_report(
        table, sels, khi_t, klo_t, phi[hits], plo[hits],
        fp_bits=FP_BITS, n_buckets=nb)
    assert np.asarray(sels).any(), "need adapted slots to kick through"
    extra = np.setdiff1d(random_keys(rng, 256), keys)[:96]
    ehi, elo = _pair(extra)
    t2, s2, kh2, kl2, st2, ok2 = insert_bulk_adaptive(
        table, sels, khi_t, klo_t, ehi, elo, fp_bits=FP_BITS, n_buckets=nb,
        evict_rounds=16, stash=stash, block=128, emulate=True)
    ok2 = np.asarray(ok2)
    assert ok2.any()
    allhi = jnp.concatenate([hi, ehi[ok2]])
    alllo = jnp.concatenate([lo, elo[ok2]])
    mem = np.asarray(kops.adaptive_lookup(t2, s2, allhi, alllo,
                                          fp_bits=FP_BITS, n_buckets=nb,
                                          stash=st2))
    assert mem.all(), "FN after kicking through adapted state"


# ------------------------------------------------ kick-storm regression --


def _adapted_state_with_repairs(rng, nb, bs, slots):
    """Build a filter with adapted selectors and return the repaired FP
    probes: (planes, stash, member (hi, lo), repaired (hi, lo))."""
    keys = random_keys(rng, 128)
    hi, lo = _pair(keys)
    table, sels, khi_t, klo_t, stash, ok = insert_bulk_adaptive(
        *_zero_planes(nb, bs), hi, lo, fp_bits=FP_BITS, n_buckets=nb,
        evict_rounds=8, stash=make_stash(slots), block=64, emulate=True)
    assert np.asarray(ok).all()
    probes = np.setdiff1d(random_keys(rng, 4096), keys)
    phi, plo = _pair(probes)
    hits = np.asarray(kops.adaptive_lookup(table, sels, phi, plo,
                                           fp_bits=FP_BITS, n_buckets=nb,
                                           stash=stash))
    assert hits.any(), "FP_BITS=12 over 4096 probes must yield FPs"
    table, sels, adapted, _ = kops.adaptive_report(
        table, sels, khi_t, klo_t, phi[hits], plo[hits],
        fp_bits=FP_BITS, n_buckets=nb)
    adapted = np.asarray(adapted)
    assert adapted.any(), "at least one table FP must adapt"
    rhi, rlo = phi[hits][adapted], plo[hits][adapted]
    gone = np.asarray(kops.adaptive_lookup(table, sels, rhi, rlo,
                                           fp_bits=FP_BITS, n_buckets=nb,
                                           stash=stash))
    assert not gone.any(), "adapted FPs must stop hitting before the storm"
    return (table, sels, khi_t, klo_t), stash, (hi, lo), (rhi, rlo)


def _kick_storm(planes, stash, rng, nb, n_extra):
    """Drive the filter to ~0.9 load with a deep eviction budget so chains
    kick through (and reset) adapted slots."""
    table, sels, khi_t, klo_t = planes
    extra = random_keys(rng, n_extra)
    ehi, elo = _pair(extra)
    table, sels, khi_t, klo_t, stash, ok = insert_bulk_adaptive(
        table, sels, khi_t, klo_t, ehi, elo, fp_bits=FP_BITS, n_buckets=nb,
        evict_rounds=32, stash=stash, block=128, emulate=True)
    ok = np.asarray(ok)
    assert ok.sum() > n_extra // 2, "storm must mostly land to churn slots"
    return (table, sels, khi_t, klo_t), stash, (ehi, elo), ok


def test_kick_storm_over_adapted_state_zero_false_negatives(rng):
    """ISSUE-8 regression: a ~0.9-load insert storm whose eviction chains
    plough through adapted buckets loses NO member — kicks re-derive each
    victim's geometry from the mirror key planes, so movement can shed a
    repair (see below) but never sheds membership."""
    nb, bs = 64, 4
    planes, stash, (hi, lo), _ = _adapted_state_with_repairs(rng, nb, bs, 64)
    planes, stash, (ehi, elo), ok = _kick_storm(planes, stash, rng, nb, 104)
    table, sels = planes[0], planes[1]
    allhi = jnp.concatenate([hi, ehi[ok]])
    alllo = jnp.concatenate([lo, elo[ok]])
    mem = np.asarray(kops.adaptive_lookup(table, sels, allhi, alllo,
                                          fp_bits=FP_BITS, n_buckets=nb,
                                          stash=stash))
    assert mem.all(), "kick storm produced a false negative"
    load = (np.asarray(table) != 0).sum() / (nb * bs)
    assert load >= 0.85, f"storm must reach high load (got {load:.2f})"


@pytest.mark.xfail(
    strict=True,
    reason="known shed-repair: kicks write the victim with selector 0 "
           "(kernels/insert.py — movement loses a slot's adaptation), and "
           "the fp0-anchored involution keeps the FP key colliding in the "
           "relocated slot, so storms resurrect some repaired FPs until "
           "they are re-reported")
def test_kick_storm_keeps_repaired_fps_suppressed(rng):
    """Documents the repair-durability gap: after a kick storm, previously
    adapted (repaired) false positives must stay suppressed.  They do NOT —
    this is the accepted cost of selector-0 kicks; the feedback loop
    re-repairs on the next report.  strict xfail: if a future PR makes
    kicks carry selectors, this starts passing and must be promoted to a
    regular test."""
    nb, bs = 64, 4
    planes, stash, _, (rhi, rlo) = _adapted_state_with_repairs(rng, nb, bs,
                                                               64)
    planes, stash, _, _ = _kick_storm(planes, stash, rng, nb, 104)
    back = np.asarray(kops.adaptive_lookup(planes[0], planes[1], rhi, rlo,
                                           fp_bits=FP_BITS, n_buckets=nb,
                                           stash=stash))
    assert not back.any(), "a repaired FP re-appeared after the storm"


# --------------------------------------------- reputation + admission --


def test_reputation_promotes_repeat_offenders(rng):
    from repro.adaptive import ReputationConfig, ReputationManager

    mgr = ReputationManager(ReputationConfig(promote_after=2,
                                             side_table_max=4))
    keys = np.arange(1, 7, dtype=np.uint64)
    assert not mgr.seen(keys).any()
    assert not mgr.observe(keys).any(), "first report never promotes"
    assert mgr.seen(keys).all()
    promoted = mgr.observe(keys)          # second report -> promotion...
    assert promoted[:4].all() and not promoted[4:].any(), \
        "...capped at side_table_max"
    assert mgr.promoted == 4
    np.testing.assert_array_equal(
        mgr.denied(keys), np.array([1, 1, 1, 1, 0, 0], dtype=bool))
    # promoted keys stop counting; unpromoted keep their counts
    assert int(keys[0]) not in mgr.counts
    assert mgr.counts[int(keys[4])] == 2


def test_membership_admission_defers_cold_reports(rng):
    """While the hysteresis controller is tripped, cold (never-seen)
    reports stay host-side; keys with prior reputation still adapt."""
    from repro.adaptive import (AdaptiveConfig, AdaptiveMembership,
                                ReputationConfig)
    from repro.streaming.admission import AdmissionConfig

    m = AdaptiveMembership(
        AdaptiveConfig(n_buckets=64, bucket_size=4, fp_bits=FP_BITS,
                       backend="jnp"),
        reputation=ReputationConfig(promote_after=3),
        admission=AdmissionConfig(high_water=0.85, low_water=0.60))
    members = random_keys(rng, 128)
    assert m.insert(members).all()
    probes = np.setdiff1d(random_keys(rng, 4096), members)
    fps = probes[m.lookup(probes)]
    assert fps.size >= 2, "need a few FPs to split warm/cold"
    warm, cold = fps[:1], fps[1:]
    m.report(warm)                        # warm gains reputation while open
    # trip the controller by pinning the congestion signal high
    m.admission.filt = type("F", (), {"fills": lambda s: (1.0, 1.0)})()
    assert not m.admission.peek()
    before = m.deferred_reports
    m.report(np.concatenate([warm, cold]))
    assert m.deferred_reports == before + cold.size, \
        "cold reports must defer while tripped"
    assert not m.filt.lookup(warm).any(), \
        "reputed key must still reach the device and adapt"
    # deferred cold reports DID gain reputation -> admitted when re-offered
    assert m.reputation.seen(cold).all()
    m.admission.filt = m.filt             # congestion relieved
    assert m.admission.peek()
    m.report(cold)
    assert not m.filt.lookup(cold).any()
    # zero false negatives through every tier
    assert m.lookup(members).all()
