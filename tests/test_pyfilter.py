"""Oracle cuckoo-filter semantics."""
import numpy as np
import pytest

from repro.core import PyCuckooFilter

from conftest import random_keys

pytestmark = pytest.mark.tier1


def test_insert_lookup_no_false_negatives(rng):
    f = PyCuckooFilter(n_buckets=2048, bucket_size=4, fp_bits=16)
    keys = random_keys(rng, 4000)
    ok = f.bulk_insert(keys)
    assert ok.all()
    assert f.bulk_lookup(keys).all()


def test_false_positive_rate_bounded(rng):
    f = PyCuckooFilter(n_buckets=2048, bucket_size=4, fp_bits=16)
    keys = random_keys(rng, 4000)
    f.bulk_insert(keys)
    absent = random_keys(rng, 20000)
    fp_rate = f.bulk_lookup(absent).mean()
    # theory: ~2*b*O/2^f = 2*4*0.49/65536 ~ 6e-5; allow 10x headroom
    assert fp_rate < 6e-4


def test_delete_removes_and_preserves_others(rng):
    f = PyCuckooFilter(n_buckets=1024, bucket_size=4, fp_bits=16)
    keys = random_keys(rng, 2000)
    f.bulk_insert(keys)
    assert f.bulk_delete(keys[:1000]).all()
    assert f.bulk_lookup(keys[1000:]).all()
    assert f.count == 1000


def test_insert_failure_rolls_back(rng):
    f = PyCuckooFilter(n_buckets=8, bucket_size=4, fp_bits=16,
                       max_displacements=16)
    keys = random_keys(rng, 200)
    ok = f.bulk_insert(keys)
    assert not ok.all(), "tiny filter must eventually fill"
    inserted = keys[ok]
    # Transactional failure: everything successfully inserted still present.
    assert f.bulk_lookup(inserted).all()
    assert f.count == int(ok.sum())


def test_duplicate_keys_supported(rng):
    f = PyCuckooFilter(n_buckets=256, bucket_size=4, fp_bits=16)
    key = random_keys(rng, 1)
    for _ in range(5):
        assert f.insert(int(key[0]))
    for _ in range(5):
        assert f.delete(int(key[0]))
    assert not f.lookup(int(key[0]))
